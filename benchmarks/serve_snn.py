"""Streaming SNN serving: throughput AND latency of the continuous-batching
engine over persistent V_MEM slots.

Three row families:

  * ``serve_snn_s*`` — the classic tick-by-tick drain (K=1, one page)
    swept over offered input sparsity, plus the device event-list backend
    serving the same workload (its kernel-counter ledger rides along as
    the gated ``pallas_events`` fraction);
  * ``serve_snn_mega_*`` — the same workload served at scale: K-frame
    megasteps (one device dispatch advances every lane K ticks) over a
    paged V-slot pool with double-buffered frame upload. Reports the
    sustained frames/s speedup over the committed ``serve_snn_s85``
    baseline figure (report-only: wall-clock) — the engine outputs are
    bit-identical to the K=1 drain, so ``skipped_rows``/``instr`` are
    gated against the same values;
  * ``serve_snn_poisson_*`` — offered-load serving: seeded Poisson
    arrivals on the engine's frame clock, reporting p50/p99 per-request
    latency (queueing + service, in frame ticks scaled by the measured
    tick rate). Lanes never interact, so the gated ``skipped_rows`` /
    ``instr`` values are schedule-independent.
  * ``serve_snn_mesh_*`` — the serving launcher on a forced-host device
    mesh (lanes over data, row tiles over model), run in a subprocess
    because the simulated device count must be set before jax
    initialises. Entirely report-only (wall-clock scaling on simulated
    CPU devices carries no perf claim; the *correctness* claim — mesh
    outputs bit-identical to single-device — is CI's mesh test suite).

Gated keys (tools/bench_gate.py): ``skipped_rows`` (pooled per-slot
skipped-work fraction; silent (frame, input-row) pairs over all gate
sites), ``pallas_events`` (device ledger fraction), ``instr`` (pooled
executed instruction cycles, two-sided). Deterministic: request rasters
and arrival schedules are seeded and the encoder reproduces the rasters
exactly (currents scaled by the encoder threshold). Wall-clock
(``frames_per_s``/``words_per_s``/``p50_ms``/``p99_ms``/``speedup``) is
report-only — CI CPUs are noisy; the TPU target is where the fused
kernel's latency matters.
"""
from __future__ import annotations

import json
import pathlib
import re
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.impulse_snn import get_snn_config
from repro.core import pipeline, snn
from repro.launch.serve_snn import make_requests
from repro.serve import SNNServeEngine

SWEEP = (0.5, 0.85)


def _serve_row(program, cfg, sparsity: float, *, n_requests: int,
               n_words: int, slots: int, seed: int = 0,
               backend: str = "int_ref", step_kw: dict = None,
               key: str = None, pages: int = 1, megastep: int = 1,
               double_buffer: bool = False, poisson_gap: float = None,
               latency: bool = False, speedup_vs: float = None,
               metrics: dict = None) -> str:
    def build():
        return SNNServeEngine(program, batch_slots=slots, backend=backend,
                              step_kw=({"use_sparse": True}
                                       if step_kw is None else step_kw),
                              pages=pages, megastep=megastep,
                              double_buffer=double_buffer)
    # warmup drain on a throwaway engine: every dispatch shape this config
    # uses gets compiled outside the timed region (jit caches are global),
    # so rows measure steady-state serving, not first-call compilation
    warm = build()
    for req in make_requests(program, 1, 1, cfg.timesteps, sparsity, seed):
        warm.submit(req)
    warm.run_until_drained(max_ticks=100_000)
    eng = build()
    for req in make_requests(program, n_requests, n_words, cfg.timesteps,
                             sparsity, seed, poisson_gap=poisson_gap):
        eng.submit(req)
    t0 = time.perf_counter()
    done = eng.run_until_drained(max_ticks=100_000)
    dt = time.perf_counter() - t0
    frames = sum(r.ticks for r in done)
    fps = frames / dt
    rep = eng.aggregate_report()
    counts = rep.instruction_counts()
    tag = f"{int(round(sparsity * 100)):02d}"
    extra = ""
    if megastep > 1 or pages > 1:
        extra += f"K={megastep} pages={pages} "
    if latency:
        # per-request latency on the frame clock (arrival -> finish tick),
        # scaled by the measured wall time per clock tick — report-only
        lats = np.array([r.latency_ticks for r in done
                         if r.latency_ticks is not None], np.float64)
        ms_per_tick = dt / max(eng.clock, 1) * 1e3
        extra += (f"p50_ms={np.percentile(lats, 50) * ms_per_tick:.2f} "
                  f"p99_ms={np.percentile(lats, 99) * ms_per_tick:.2f} ")
    if speedup_vs:
        extra += f"speedup={fps / speedup_vs:.1f}x "
    if eng.device_row_events is not None:
        # the kernel's own executed-skip ledger — closes against the
        # per-slot raster accounting at any occupancy now that vacated
        # lanes are re-seeded with zero state — gated like the
        # granularity rows
        extra += f"pallas_events={eng.device_skipped_row_fraction():.3f} "
    row = emit(
        key or f"serve_snn_s{tag}", dt / max(eng.ticks, 1) * 1e6,
        f"frames_per_s={fps:.1f} "
        f"words_per_s={frames / cfg.timesteps / dt:.1f} "
        f"skipped_rows={rep.skipped_row_fraction:.3f} {extra}"
        f"instr={counts.total} offered={sparsity:.2f} reqs={len(done)}")
    if metrics is not None:
        metrics[key or f"serve_snn_s{tag}"] = fps
    return row


def _mesh_row(quick: bool) -> str:
    """Serving over a (2, 2) forced-host mesh via the launcher subprocess.

    The row never fails the gate: when the subprocess cannot run (no
    XLA CPU multi-device support in this build) it reports
    ``mesh=unavailable`` instead of a ``*_FAILED`` row — the bit-identity
    contract is enforced by tests/test_mesh_snn.py, not here."""
    import os
    import subprocess
    import sys
    repo = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(repo / "src"), env.get("PYTHONPATH")) if p)
    cmd = [sys.executable, "-m", "repro.launch.serve_snn", "--mesh", "2,2",
           "--megastep", "4", "--pages", "2"]
    if quick:
        cmd.append("--quick")
    t0 = time.perf_counter()
    try:
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=900, cwd=repo, env=env)
        dt = time.perf_counter() - t0
        m = re.search(r"\(([\d.]+) frames/s", out.stdout)
        if out.returncode != 0 or m is None:
            raise RuntimeError(out.stderr.strip().splitlines()[-1:]
                               or "no frames/s in output")
        derived = (f"frames_per_s={float(m.group(1)):.1f} mesh=2x2 "
                   f"wall_s={dt:.1f}")
    except (RuntimeError, subprocess.SubprocessError) as e:
        dt = time.perf_counter() - t0
        derived = f"mesh=unavailable wall_s={dt:.1f} ({e})"
    return emit("serve_snn_mesh_d2m2", dt * 1e6, derived)


def _committed_fps(name: str) -> float:
    """frames_per_s of a row in the committed quick baseline, if present —
    the megastep speedup is quoted against the committed ``serve_snn_s85``
    figure (the acceptance bar), not the same-run K=1 row, which itself
    benefits from the shared jitted dispatch."""
    path = pathlib.Path(__file__).parent / "baseline_quick.json"
    try:
        rows = json.loads(path.read_text())["rows"]
    except (OSError, ValueError, KeyError):
        return None
    for r in rows:
        if r["name"] == name:
            m = re.search(r"frames_per_s=([\d.]+)", r.get("derived", ""))
            if m:
                return float(m.group(1))
    return None


def run(quick: bool = False):
    cfg = get_snn_config("impulse-imdb")
    params = snn.init_fc_snn(jax.random.PRNGKey(0), cfg)
    program = pipeline.compile_network(cfg, params, domain="int")
    n_requests, n_words, slots = (4, 2, 2) if quick else (12, 6, 4)
    metrics = {}
    rows = [_serve_row(program, cfg, s, n_requests=n_requests,
                       n_words=n_words, slots=slots, metrics=metrics)
            for s in SWEEP]
    # the device event-list backend serving the same 0.85 workload: the
    # engine's kernel-counter ledger rides along as the gated
    # ``pallas_events`` fraction (interpret mode; wall-clock is TPU-only)
    rows.append(_serve_row(
        program, cfg, 0.85, n_requests=n_requests, n_words=n_words,
        slots=slots, backend="pallas_events",
        step_kw={"interpret": True, "block_b": slots},
        key="serve_snn_events_s85"))
    # megastep serving at scale: same workload, K=8 frames per dispatch
    # over a 2-page pool with double-buffered upload — bit-identical
    # outputs, so skipped_rows/instr gate against the K=1 values; the
    # frames/s speedup over the committed serve_snn_s85 figure is the
    # tentpole number
    rows.append(_serve_row(
        program, cfg, 0.85, n_requests=n_requests, n_words=n_words,
        slots=slots, pages=2, megastep=8, double_buffer=True, latency=True,
        speedup_vs=_committed_fps("serve_snn_s85") or
        metrics["serve_snn_s85"], key="serve_snn_mega_s85"))
    rows.append(_serve_row(
        program, cfg, 0.85, n_requests=n_requests, n_words=n_words,
        slots=slots, backend="pallas_events", pages=2, megastep=4,
        step_kw={"interpret": True, "block_b": slots},
        key="serve_snn_mega_events_s85"))
    # offered-load latency: seeded Poisson arrivals at roughly half the
    # engine's lane capacity — p50/p99 are the serving latency numbers
    gap = float(cfg.timesteps * n_words) / (2 * slots)
    rows.append(_serve_row(
        program, cfg, 0.85, n_requests=n_requests, n_words=n_words,
        slots=slots, pages=2, megastep=8, double_buffer=True,
        poisson_gap=gap, latency=True, key="serve_snn_poisson_s85"))
    # mesh-sharded serving (subprocess: forced host devices) — report-only
    rows.append(_mesh_row(quick))
    return rows


if __name__ == "__main__":
    run()
