"""Streaming SNN serving throughput: the continuous-batching engine over
persistent V_MEM slots, swept over offered input sparsity.

Per offered sparsity the row reports tick wall-clock plus:

  * ``frames_per_s`` / ``words_per_s`` — engine throughput (report-only:
    CI CPUs are noisy; the TPU target is where the fused kernel's latency
    matters);
  * ``skipped_rows`` — the pooled per-slot skipped-work fraction (silent
    (frame, input-row) pairs over all gate sites), accumulated tick by
    tick from the engine's per-request event accounting. Deterministic:
    the request rasters are seeded and the encoder reproduces them
    exactly (currents scaled by the encoder threshold), so this is the
    executed sparsity win — pinned by tools/bench_gate.py;
  * ``instr`` — pooled executed instruction cycles (exact function of the
    rasters; two-sided gate);
  * ``offered`` — the input sparsity the requests were generated at
    (workload statistic, report-only).

The skipped fraction tracks offered sparsity at the input layer and
regresses toward the trained-activity level in deeper layers — same
structure as benchmarks/sparsity_gating.py measures, here produced by the
*serving* path (per-slot accounting summed over staggered requests) rather
than a monolithic batch run.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.configs.impulse_snn import get_snn_config
from repro.core import pipeline, snn
from repro.launch.serve_snn import make_requests
from repro.serve import SNNServeEngine

SWEEP = (0.5, 0.85)


def _serve_row(program, cfg, sparsity: float, *, n_requests: int,
               n_words: int, slots: int, seed: int = 0,
               backend: str = "int_ref", step_kw: dict = None,
               key: str = None) -> str:
    eng = SNNServeEngine(program, batch_slots=slots, backend=backend,
                         step_kw=({"use_sparse": True} if step_kw is None
                                  else step_kw))
    for req in make_requests(program, n_requests, n_words, cfg.timesteps,
                             sparsity, seed):
        eng.submit(req)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    frames = sum(r.ticks for r in done)
    rep = eng.aggregate_report()
    counts = rep.instruction_counts()
    tag = f"{int(round(sparsity * 100)):02d}"
    extra = ""
    if eng.device_row_events is not None:
        # the kernel's own executed-skip ledger (equal-length request
        # batches keep every lane occupied, so it closes against the
        # per-slot raster accounting) — gated like the granularity rows
        extra = f"pallas_events={eng.device_skipped_row_fraction():.3f} "
    return emit(
        key or f"serve_snn_s{tag}", dt / max(eng.ticks, 1) * 1e6,
        f"frames_per_s={frames / dt:.1f} "
        f"words_per_s={frames / cfg.timesteps / dt:.1f} "
        f"skipped_rows={rep.skipped_row_fraction:.3f} {extra}"
        f"instr={counts.total} offered={sparsity:.2f} reqs={len(done)}")


def run(quick: bool = False):
    cfg = get_snn_config("impulse-imdb")
    params = snn.init_fc_snn(jax.random.PRNGKey(0), cfg)
    program = pipeline.compile_network(cfg, params, domain="int")
    n_requests, n_words, slots = (4, 2, 2) if quick else (12, 6, 4)
    rows = [_serve_row(program, cfg, s, n_requests=n_requests,
                       n_words=n_words, slots=slots) for s in SWEEP]
    # the device event-list backend serving the same 0.85 workload: the
    # engine's kernel-counter ledger rides along as the gated
    # ``pallas_events`` fraction (interpret mode; wall-clock is TPU-only)
    rows.append(_serve_row(
        program, cfg, 0.85, n_requests=n_requests, n_words=n_words,
        slots=slots, backend="pallas_events",
        step_kw={"interpret": True, "block_b": slots},
        key="serve_snn_events_s85"))
    return rows


if __name__ == "__main__":
    run()
