"""Event-gated vs dense execution of the fused SNN network kernel.

Sweeps synthetic input sparsity 0 -> 0.95 plus the trained IMDB encoder
raster through both execution paths and reports wall-clock and the
skipped-tile fraction (the fraction of (timestep, layer, batch-tile) MXU
matmuls the gate eliminated).

Granularity matters: the kernel gates whole (timestep, batch-tile) spike
tiles, so unstructured (iid Bernoulli) sparsity almost never yields an
all-silent 128-lane tile — a 0.85-sparse iid raster skips ~nothing. Real
SNN rasters are temporally bursty (words arrive, then the net goes quiet),
which is the structure the gate exploits. The synthetic generator therefore
factors sparsity into (active-timestep probability) x (within-frame lane
density): at 85% sparsity, 30% of timesteps carry spikes at 50% density —
the same overall event count an iid raster would have, but event-driven
hardware (and this kernel) can skip the silent 70%. A `bernoulli` row is
emitted alongside as the honest granularity control.

Wall-clock notes: the `ref` rows time the jit'd lax.cond-gated scan on the
host (real skipped work); `pallas` interpret-mode timing on a shared CPU is
noisy and only the TPU target measures the kernel's real latency — the
skipped-tile fraction is the stable, machine-independent signal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.fused_snn_net.ops import fused_snn_net

SWEEP = (0.0, 0.25, 0.5, 0.75, 0.85, 0.95)


def synthetic_raster(rng, T: int, B: int, N: int, sparsity: float,
                     structure: str = "temporal") -> np.ndarray:
    """int8 spike raster at the requested overall sparsity.

    ``temporal``: silence concentrates in whole timesteps (active-timestep
    probability p_t, within-frame density d, p_t * d = 1 - sparsity) — the
    bursty structure trained SNN rasters exhibit. ``bernoulli``: iid events
    (the granularity control; tile-level gating cannot exploit it)."""
    occ = 1.0 - sparsity
    if structure == "bernoulli":
        return (rng.random((T, B, N)) < occ).astype(np.int8)
    density = max(occ, 0.5)
    p_t = occ / density
    active_t = rng.random(T) < p_t
    frames = (rng.random((T, B, N)) < density).astype(np.int8)
    return frames * active_t[:, None, None].astype(np.int8)


def _stack(rng, n0: int = 128, hidden: int = 128, n_out: int = 2) -> list:
    return [jnp.asarray(rng.integers(-31, 32, s).astype(np.int8))
            for s in ((n0, hidden), (hidden, hidden), (hidden, n_out))]


def _skip_fraction(skips, timesteps: int) -> float:
    s = np.asarray(skips)
    return float(s.sum()) / float(timesteps * s.shape[0] * s.shape[1])


def run(quick: bool = False) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    T, B, block_b = (24, 4, 2) if quick else (48, 8, 2)
    ws = _stack(rng)
    # IF neurons propagate silence through the stack (no leak, hard reset:
    # a silent input frame cannot produce output spikes), so the whole-
    # network skip fraction tracks input burstiness. RMP/LIF layers can
    # re-fire/leak during silent steps — the trained-IMDB row below shows
    # that regime.
    kw = dict(thresholds=(60, 60), leaks=(2, 2), neuron="if",
              clamp_mode="saturate")
    reps = dict(repeats=2, warmup=1) if quick else dict(repeats=3, warmup=1)
    sweep = (0.0, 0.85) if quick else SWEEP

    for s in sweep:
        spikes = jnp.asarray(synthetic_raster(rng, T, B, 128, s))
        meas = float(1.0 - np.asarray(spikes).mean())
        us_d = time_call(lambda: fused_snn_net(
            spikes, ws, use_pallas=False, **kw)[1][-1], **reps)
        us_g = time_call(lambda: fused_snn_net(
            spikes, ws, use_pallas=False, use_sparse=True, **kw)[1][-1],
            **reps)
        _, _, skips = fused_snn_net(spikes, ws, interpret=True,
                                    block_b=block_b, use_sparse=True, **kw)
        frac = _skip_fraction(skips, T)
        rows.append(emit(
            f"gating_temporal_{int(s*100):02d}", us_g,
            f"dense_us={us_d:.1f} speedup={us_d/us_g:.2f}x "
            f"skipped_tiles={frac:.3f} measured_sparsity={meas:.3f}"))

    # granularity control: iid events at 85% sparsity gate ~nothing
    spikes = jnp.asarray(synthetic_raster(rng, T, B, 128, 0.85, "bernoulli"))
    _, _, skips = fused_snn_net(spikes, ws, interpret=True, block_b=block_b,
                                use_sparse=True, **kw)
    rows.append(emit("gating_bernoulli_85", 0.0,
                     f"skipped_tiles={_skip_fraction(skips, T):.3f} "
                     "(iid events defeat tile-level gating)"))

    # pallas interpret wall-clock (noisy on CPU; TPU is the target)
    if not quick:
        spikes = jnp.asarray(synthetic_raster(rng, T, B, 128, 0.85))
        us_pd = time_call(lambda: fused_snn_net(
            spikes, ws, interpret=True, block_b=block_b, **kw)[1][-1], **reps)
        us_pg = time_call(lambda: fused_snn_net(
            spikes, ws, interpret=True, block_b=block_b, use_sparse=True,
            **kw)[1][-1], **reps)
        rows.append(emit("gating_pallas_interpret_85", us_pg,
                         f"dense_us={us_pd:.1f} (interpret-mode; "
                         "wall-clock meaningful on TPU only)"))

    # conv workload: event gating on the im2col patch rasters of an int
    # conv program (per-(timestep, position-tile) MXU gates)
    rows += _conv_rows(quick)
    # the trained IMDB raster through the deployed integer program
    rows += _imdb_rows(quick)
    return rows


def _conv_rows(quick: bool) -> list[str]:
    """A LeNet-style int conv program on the event-gated backend: the conv
    front-end gates per (timestep, batch*position tile) on the patch
    raster, the fc stack per (timestep, batch tile) — sparse conv inputs
    (direct-encoded dim images) skip patch-tile matmuls too."""
    from repro.configs.base import SpikingConfig
    from repro.configs.impulse_snn import SNNModelConfig
    from repro.core import pipeline, snn

    cfg = SNNModelConfig(
        arch_id="lenet-gate", conv_spec=((6, 3, 1), (8, 3, 2), (8, 3, 1)),
        in_shape=(10, 10, 1), layer_sizes=(5 * 5 * 8, 32, 4),
        spiking=SpikingConfig(neuron="if", timesteps=2 if quick else 4,
                              threshold=1.0, leak=0.0625,
                              w_bits=6, v_bits=11),
        timesteps=2 if quick else 4, task="multiclass")
    params = snn.init_lenet_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    # dim, mostly-dark frames: most encoder positions stay silent, the
    # bursty-at-position granularity the patch-tile gate can exploit
    x = jnp.asarray((rng.random((4, *cfg.in_shape)) < 0.08)
                    .astype(np.float32)) * 3.0
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_static(x, cfg.timesteps)
    res = pipeline.run_network(program, xs, "pallas_sparse", interpret=True,
                               block_b=4)
    rep = pipeline.sparsity_report(program, res.rasters)
    conv_skips = res.aux["conv_skip_counts"]
    fracs = []
    for sk, spec in zip(conv_skips, program.int_conv_stack):
        sk = np.asarray(sk)
        fracs.append(float(sk.sum()) / (cfg.timesteps * sk.shape[0]))
    return [emit(
        "gating_conv_lenet", 0.0,
        f"conv_skipped_tiles={fracs[0]:.3f}/{fracs[1]:.3f} "
        f"fc_skipped_tiles={res.aux['skipped_tile_fraction']:.3f} "
        f"patch_sparsity={rep.layer_sparsity[0]:.3f}")]


def _imdb_rows(quick: bool) -> list[str]:
    from repro.configs.impulse_snn import IMDB
    from repro.core import pipeline, snn
    from repro.data import make_sentiment_vocab, sentiment_batch
    from repro.optim import adamw, apply_updates

    ds = make_sentiment_vocab(0)
    params = snn.init_fc_snn(jax.random.PRNGKey(0), IMDB)
    opt = adamw(lambda s: 2e-3, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, _), g = jax.value_and_grad(snn.sentiment_loss, has_aux=True)(
            params, x, y, IMDB)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    for s in range(8 if quick else 60):
        xb, yb = sentiment_batch(ds, 64, 12, seed=s)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(xb),
                                    jnp.asarray(yb))

    program = pipeline.compile_network(IMDB, params, domain="int")
    xb, _ = sentiment_batch(ds, 8 if quick else 16, 12, seed=99)
    xs = pipeline.present_words(jnp.asarray(xb), IMDB.timesteps)
    res = pipeline.run_network(program, xs, "pallas_sparse", interpret=True,
                               block_b=4)
    rep = pipeline.sparsity_report(program, res.rasters)
    return [emit(
        "gating_imdb_trained", 0.0,
        f"skipped_tiles={res.aux['skipped_tile_fraction']:.3f} "
        f"input_sparsity={rep.layer_sparsity[0]:.3f} "
        f"overall_sparsity={rep.overall_sparsity:.3f} "
        f"silent_steps={rep.silent_timestep_fraction[0]:.3f}")]


if __name__ == "__main__":
    run()
