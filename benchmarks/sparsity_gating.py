"""Event-gated vs dense execution of the fused SNN network kernel, across
gating granularities.

Sweeps synthetic input sparsity 0 -> 0.95 plus the trained IMDB encoder
raster through the execution paths and reports wall-clock plus the
skipped-work fraction at every gate granularity: whole-tile (`tile`,
fraction of (timestep, layer, batch-tile) MXU matmuls eliminated),
row-block (`blockG`, fraction of 128/G-lane partial matmuls eliminated),
and the spike-list compaction executor (`events`, fraction of silent
(frame, input-row) pairs — the upper bound any gate can reach, and what
event-driven silicon skips).

Granularity matters: a whole-tile gate needs an all-silent 128-lane tile,
so unstructured (iid Bernoulli) sparsity at 0.85 skips ~nothing there —
but the event-list executor skips exactly 85% of row work on the same
raster, and row blocks recover most of the win whenever silence clusters
in lanes. The synthetic generator therefore emits three structures:
``temporal`` (silence concentrates in whole timesteps — the bursty shape
trained SNN rasters exhibit; any granularity skips it), ``bernoulli``
(iid events — only the event list exploits it), and ``spatial`` (activity
clusters in a lane span, as in im2col patch rasters of dim image borders —
row blocks exploit it, whole tiles cannot).

Wall-clock notes: the `ref` rows time the jit'd lax.cond-gated scan on the
host (real skipped work); `pallas` interpret-mode timing on a shared CPU is
noisy and only the TPU target measures the kernel's real latency — the
skipped-work fractions are the stable, machine-independent signals (pinned
against a committed baseline by tools/bench_gate.py in CI).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.fused_snn_net.events import fused_snn_net_events
from repro.kernels.fused_snn_net.ops import (fused_snn_net,
                                             fused_snn_net_device_events)

SWEEP = (0.0, 0.25, 0.5, 0.75, 0.85, 0.95)


def synthetic_raster(rng, T: int, B: int, N: int, sparsity: float,
                     structure: str = "temporal") -> np.ndarray:
    """int8 spike raster at the requested overall sparsity.

    ``temporal``: silence concentrates in whole timesteps (active-timestep
    probability p_t, within-frame density d, p_t * d = 1 - sparsity) — the
    bursty structure trained SNN rasters exhibit. ``bernoulli``: iid events
    (the granularity control; tile-level gating cannot exploit it).
    ``spatial``: events cluster in a leading lane span (span fraction p_l,
    within-span density d, p_l * d = 1 - sparsity) — the structure row-
    block gating exploits and whole-tile gating cannot."""
    occ = 1.0 - sparsity
    if structure == "bernoulli":
        return (rng.random((T, B, N)) < occ).astype(np.int8)
    density = max(occ, 0.5)
    if structure == "spatial":
        span = max(1, round(occ / density * N))
        frames = np.zeros((T, B, N), np.int8)
        frames[:, :, :span] = rng.random((T, B, span)) < density
        return frames
    p_t = occ / density
    active_t = rng.random(T) < p_t
    frames = (rng.random((T, B, N)) < density).astype(np.int8)
    return frames * active_t[:, None, None].astype(np.int8)


def _stack(rng, n0: int = 128, hidden: int = 128, n_out: int = 2) -> list:
    return [jnp.asarray(rng.integers(-31, 32, s).astype(np.int8))
            for s in ((n0, hidden), (hidden, hidden), (hidden, n_out))]


def _skip_fraction(skips, timesteps: int) -> float:
    """Fraction of gate sites skipped: (tile, layer) pairs at granularity 1
    (one array), (tile, layer, block) triples at finer granularities (a
    per-layer list of arrays — block sites weight by count, which tracks
    work because blocks within a layer are equal-width)."""
    if isinstance(skips, list):
        total = sum(int(np.asarray(s).sum()) for s in skips)
        sites = sum(np.asarray(s).shape[0] * np.asarray(s).shape[1]
                    for s in skips)
        return float(total) / float(timesteps * sites)
    s = np.asarray(skips)
    return float(s.sum()) / float(timesteps * s.shape[0] * s.shape[1])


def _granularity_fractions(spikes, ws, kw, T: int, block_b: int,
                           grans: tuple) -> str:
    """One raster, every gate granularity: tile (G=1), row blocks, the host
    event-list executor's skipped-row fraction (the upper bound), and the
    device event-list kernel's executed skip fraction (`pallas_events`,
    from the kernel's own per-row counters — must equal the host bound)."""
    parts = []
    for g in (1,) + tuple(grans):
        _, _, skips = fused_snn_net(spikes, ws, interpret=True,
                                    block_b=block_b, use_sparse=True,
                                    gate_granularity=g, **kw)
        name = "tile" if g == 1 else f"block{g}"
        parts.append(f"{name}={_skip_fraction(skips, T):.3f}")
    _, _, stats = fused_snn_net_events(np.asarray(spikes), ws, **kw)
    parts.append(f"events={stats.skipped_row_fraction:.3f}")
    _, _, dstats = fused_snn_net_device_events(spikes, ws, interpret=True,
                                               block_b=block_b, **kw)
    parts.append(f"pallas_events={dstats.skipped_row_fraction:.3f}")
    return " ".join(parts)


def run(quick: bool = False) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    T, B, block_b = (24, 4, 2) if quick else (48, 8, 2)
    ws = _stack(rng)
    # IF neurons propagate silence through the stack (no leak, hard reset:
    # a silent input frame cannot produce output spikes), so the whole-
    # network skip fraction tracks input burstiness. RMP/LIF layers can
    # re-fire/leak during silent steps — the trained-IMDB row below shows
    # that regime.
    kw = dict(thresholds=(60, 60), leaks=(2, 2), neuron="if",
              clamp_mode="saturate")
    reps = dict(repeats=2, warmup=1) if quick else dict(repeats=3, warmup=1)
    sweep = (0.0, 0.85) if quick else SWEEP

    for s in sweep:
        spikes = jnp.asarray(synthetic_raster(rng, T, B, 128, s))
        meas = float(1.0 - np.asarray(spikes).mean())
        us_d = time_call(lambda: fused_snn_net(
            spikes, ws, use_pallas=False, **kw)[1][-1], **reps)
        us_g = time_call(lambda: fused_snn_net(
            spikes, ws, use_pallas=False, use_sparse=True, **kw)[1][-1],
            **reps)
        _, _, skips = fused_snn_net(spikes, ws, interpret=True,
                                    block_b=block_b, use_sparse=True, **kw)
        frac = _skip_fraction(skips, T)
        rows.append(emit(
            f"gating_temporal_{int(s*100):02d}", us_g,
            f"dense_us={us_d:.1f} speedup={us_d/us_g:.2f}x "
            f"skipped_tiles={frac:.3f} measured_sparsity={meas:.3f}"))

    # granularity sweep at 85% sparsity: tile vs row-block vs event-list
    # across the three raster structures. ``bernoulli`` (iid) is the
    # acceptance row: tile gating skips ~nothing, the event list skips the
    # full 0.85 of row work; ``spatial`` is where row blocks recover most
    # of the event-list bound; ``temporal`` is skippable at any
    # granularity.
    grans = (8,) if quick else (2, 4, 8)
    for structure in ("temporal", "bernoulli", "spatial"):
        spikes = jnp.asarray(synthetic_raster(rng, T, B, 128, 0.85,
                                              structure))
        rows.append(emit(
            f"gating_granularity_{structure}_85", 0.0,
            _granularity_fractions(spikes, ws, kw, T, block_b, grans)))

    # pallas interpret wall-clock (noisy on CPU; TPU is the target)
    if not quick:
        spikes = jnp.asarray(synthetic_raster(rng, T, B, 128, 0.85))
        us_pd = time_call(lambda: fused_snn_net(
            spikes, ws, interpret=True, block_b=block_b, **kw)[1][-1], **reps)
        us_pg = time_call(lambda: fused_snn_net(
            spikes, ws, interpret=True, block_b=block_b, use_sparse=True,
            **kw)[1][-1], **reps)
        rows.append(emit("gating_pallas_interpret_85", us_pg,
                         f"dense_us={us_pd:.1f} (interpret-mode; "
                         "wall-clock meaningful on TPU only)"))

    # conv workload: event gating on the im2col patch rasters of an int
    # conv program (per-(timestep, position-tile) MXU gates)
    rows += _conv_rows(quick)
    # the trained IMDB raster through the deployed integer program
    rows += _imdb_rows(quick)
    return rows


def _conv_rows(quick: bool) -> list[str]:
    """A LeNet-style int conv program on the event-gated backend: the conv
    front-end gates per (timestep, batch*position tile) on the patch
    raster, the fc stack per (timestep, batch tile) — sparse conv inputs
    (direct-encoded dim images) skip patch-tile matmuls too."""
    from repro.configs.base import SpikingConfig
    from repro.configs.impulse_snn import SNNModelConfig
    from repro.core import pipeline, snn

    cfg = SNNModelConfig(
        arch_id="lenet-gate", conv_spec=((6, 3, 1), (8, 3, 2), (8, 3, 1)),
        in_shape=(10, 10, 1), layer_sizes=(5 * 5 * 8, 32, 4),
        spiking=SpikingConfig(neuron="if", timesteps=2 if quick else 4,
                              threshold=1.0, leak=0.0625,
                              w_bits=6, v_bits=11),
        timesteps=2 if quick else 4, task="multiclass")
    params = snn.init_lenet_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    # dim, mostly-dark frames: most encoder positions stay silent, the
    # bursty-at-position granularity the patch-tile gate can exploit
    x = jnp.asarray((rng.random((4, *cfg.in_shape)) < 0.08)
                    .astype(np.float32)) * 3.0
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_static(x, cfg.timesteps)
    res = pipeline.run_network(program, xs, "pallas_sparse", interpret=True,
                               block_b=4)
    rep = pipeline.sparsity_report(program, res.rasters)
    conv_skips = res.aux["conv_skip_counts"]
    fracs = []
    for sk, spec in zip(conv_skips, program.int_conv_stack):
        sk = np.asarray(sk)
        fracs.append(float(sk.sum()) / (cfg.timesteps * sk.shape[0]))
    return [emit(
        "gating_conv_lenet", 0.0,
        f"conv_skipped_tiles={fracs[0]:.3f}/{fracs[1]:.3f} "
        f"fc_skipped_tiles={res.aux['skipped_tile_fraction']:.3f} "
        f"patch_sparsity={rep.layer_sparsity[0]:.3f}")]


def _imdb_rows(quick: bool) -> list[str]:
    from repro.configs.impulse_snn import IMDB
    from repro.core import pipeline, snn
    from repro.data import make_sentiment_vocab, sentiment_batch
    from repro.optim import adamw, apply_updates

    ds = make_sentiment_vocab(0)
    params = snn.init_fc_snn(jax.random.PRNGKey(0), IMDB)
    opt = adamw(lambda s: 2e-3, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, _), g = jax.value_and_grad(snn.sentiment_loss, has_aux=True)(
            params, x, y, IMDB)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    for s in range(8 if quick else 60):
        xb, yb = sentiment_batch(ds, 64, 12, seed=s)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(xb),
                                    jnp.asarray(yb))

    program = pipeline.compile_network(IMDB, params, domain="int")
    xb, _ = sentiment_batch(ds, 8 if quick else 16, 12, seed=99)
    xs = pipeline.present_words(jnp.asarray(xb), IMDB.timesteps)
    res = pipeline.run_network(program, xs, "pallas_sparse", interpret=True,
                               block_b=4)
    rep = pipeline.sparsity_report(program, res.rasters)
    rows = [emit(
        "gating_imdb_trained", 0.0,
        f"skipped_tiles={res.aux['skipped_tile_fraction']:.3f} "
        f"input_sparsity={rep.layer_sparsity[0]:.3f} "
        f"overall_sparsity={rep.overall_sparsity:.3f} "
        f"silent_steps={rep.silent_timestep_fraction[0]:.3f}")]
    # the same trained raster under the finer gates: row blocks vs the
    # event-list bound (== the report's skipped-row fraction) — the row
    # that motivated sub-tile gating in the first place
    res8 = pipeline.run_network(program, xs, "pallas_sparse",
                                interpret=True, block_b=4,
                                gate_granularity=8)
    ev = pipeline.run_network(program, xs, "ref_events")
    evd = pipeline.run_network(program, xs, "pallas_events",
                               interpret=True, block_b=4)
    rows.append(emit(
        "gating_imdb_granularity", 0.0,
        f"tile={res.aux['skipped_tile_fraction']:.3f} "
        f"block8={res8.aux['skipped_block_fraction']:.3f} "
        f"events={ev.aux['skipped_row_fraction']:.3f} "
        f"pallas_events={evd.aux['skipped_row_fraction']:.3f}"))
    return rows


if __name__ == "__main__":
    run()
