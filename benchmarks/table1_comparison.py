"""Table I: this work's row reproduced from the calibrated model (area,
supply/frequency/power points, performance/area, TOPS/W), with the paper's
reported competitor rows for context."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import energy

COMPETITORS = [
    # name, tech, type, precision, TOPS/W (as reported in Table I)
    ("VLSI15_6T", "28nm", "CIM CAM/logic", "-", None),
    ("CICC17_time", "65nm", "SNN time-based", "3b/8b", 0.019),
    ("ISSCC19_8T", "28nm", "CIM CNN/FC", "8b", 0.97),
    ("VLSI20_ZPIM", "65nm", "CIM CNN", "16b", 0.31),
    ("ASSCC20_async", "65nm", "SNN async", "1b/6b", 0.67),
]


def run() -> list[str]:
    rows = []
    rows.append(emit("table1_area", 0.0,
                     f"area={energy.AREA_MM2}mm2 mem_eff={energy.MEM_AREA_EFFICIENCY*100:.1f}% "
                     f"tech={energy.TECH_NM}nm bitcell=10T precision=6b/11b"))
    for pt in energy.OPERATING_POINTS:
        rows.append(emit(
            f"table1_this_work_{pt.name}", 1e6 / pt.freq_hz,
            f"V={pt.vdd} f={pt.freq_hz/1e6:.0f}MHz P={pt.power_w*1e3:.3f}mW "
            f"GOPS/mm2={energy.gops_per_mm2(pt):.2f} "
            f"TOPS/W={energy.tops_per_watt(pt):.2f}"))
    ours = energy.tops_per_watt(energy.POINT_D)
    for name, tech, typ, prec, topsw in COMPETITORS:
        if topsw is None:
            rows.append(emit(f"table1_{name}", 0.0, f"{tech} {typ} {prec} TOPS/W=n/a"))
        else:
            rows.append(emit(f"table1_{name}", 0.0,
                             f"{tech} {typ} {prec} TOPS/W={topsw} "
                             f"ours/theirs={ours/topsw:.2f}x"))
    rows.append(emit("table1_flexible_neuron", 0.0,
                     "this_work=IF+LIF+RMP via ISA; all competitors fixed"))
    return rows


if __name__ == "__main__":
    run()
