"""Roofline report: reads artifacts/dryrun/*.json (produced by
repro.launch.dryrun) and prints/serialises the per-(arch x shape x mesh)
roofline table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(mesh: str = "single", tag: str = "") -> list[dict]:
    cells = []
    d = ART / mesh
    if not d.exists():
        return cells
    for fp in sorted(d.glob("*.json")):
        stem = fp.stem
        if tag and not stem.endswith(f"__{tag}"):
            continue
        if not tag and stem.count("__") > 1:
            continue
        cells.append(json.loads(fp.read_text()))
    return cells


def fraction_of_roofline(c: dict) -> float:
    """compute term / max(all terms): 1.0 == compute-bound at the roofline."""
    t = c["roofline_terms_s"]
    bound = max(t.values())
    return (t["compute_s"] / bound) if bound else 0.0


def run(mesh: str = "single") -> list[str]:
    rows = []
    for c in load_cells(mesh):
        name = f"roofline_{c['arch']}_{c['shape']}"
        if c.get("skipped"):
            rows.append(emit(name, 0.0, f"SKIP: {c['skipped']}"))
            continue
        t = c["roofline_terms_s"]
        rows.append(emit(
            name, t["compute_s"] * 1e6,
            f"dom={c['dominant'].replace('_s','')} "
            f"comp={t['compute_s']:.2e}s mem={t['memory_s']:.2e}s "
            f"coll={t['collective_s']:.2e}s frac={fraction_of_roofline(c):.3f} "
            f"useful={c['useful_ratio']:.2f} "
            f"peak={c['peak_bytes_per_device']/2**30:.1f}GiB fits={c['fits_16GiB']}"))
    if not rows:
        rows.append(emit("roofline_missing", 0.0,
                         "run: python -m repro.launch.dryrun --all first"))
    return rows


if __name__ == "__main__":
    run()
